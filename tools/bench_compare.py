#!/usr/bin/env python3
"""Perf-baseline regression gate over bench/serve JSON artifacts.

Stdlib-only (CI runs it before any heavy import).  Compares the metrics
extracted from a current run's JSON against a checked-in baseline file
(``benchmarks/baselines/*.json``) and fails on out-of-band regressions —
this is what turns the bench smokes from "prints numbers" into a gate:
a future PR that silently halves occupancy, tok/s, or fu_utilization
fails CI with the offending metric named.

Inputs it understands:

* bench artifacts (``benchmarks/common.write_json``): ``{"rows":
  [{"name", "us_per_call", "derived"}, ...]}`` — every row yields
  ``<name>.us`` (when the wall time parses as a number) and one
  ``<name>.<key>`` per ``key=value`` pair in the derived string whose
  value starts with a number (units and annotations after the number
  are ignored: ``occ=0.91``, ``tok/s=1053.8``, ``ttft_ms=4.0ms`` all
  parse).
* serve metrics files (``repro.launch.serve --metrics FILE``):
  ``{"stats": {...}, "metrics": {...}}`` — numeric stats fields yield
  ``stats.<field>``, registry-snapshot entries yield
  ``metrics.<name>`` (histogram summaries flatten to
  ``metrics.<name>.p50`` etc.).

Baseline file format::

    {
      "bench": "how this baseline is produced (for humans)",
      "metrics": {"serving_continuous.occ": 0.91, ...},
      "gates": [
        {"metric": "serving_continuous.occ", "direction": "higher",
         "abs_tol": 0.0},
        {"metric": "serving_continuous.tok/s", "direction": "higher",
         "rel_tol": 0.9}
      ]
    }

Each gate compares the current value against the *baseline* value under
a tolerance band ``abs_tol + rel_tol * |baseline|`` (both default 0):
``direction: "higher"`` means higher is better — fail when ``current <
baseline - band``; ``"lower"`` means lower is better — fail when
``current > baseline + band``.  Improvements never fail.  A gated
metric missing from the current run fails loudly (a silently dropped
row must not pass the gate).

Tolerance discipline (docs/observability.md#perf-baselines): metrics
that are *deterministic* given the trace (occupancy, decode steps,
preemption counts, prefix hit rate — scheduling never branches on wall
time) carry zero/tight bands and catch any drift exactly; wall-clock
metrics (tok/s, fu_utilization) carry wide ``rel_tol`` bands sized to
catch order-of-magnitude collapses, not CI-machine jitter.

Updating a baseline after an intentional perf change::

    python tools/bench_compare.py current.json \
        benchmarks/baselines/bench_serving_smoke.json --update

rewrites the baseline's gated ``metrics`` from the current run (gates
and tolerances stay as authored) — commit the diff with the PR that
changed the numbers.  ``--list`` prints every metric extracted from the
current file, for authoring new gates.
"""
from __future__ import annotations

import argparse
import json
import re
import sys

_NUM = re.compile(r"^-?\d+(?:\.\d+)?(?:[eE][+-]?\d+)?")


def _lead_float(v) -> float | None:
    """Leading number of a value ('0.91', '1.17x', '4.0ms' all parse)."""
    if isinstance(v, (int, float)) and not isinstance(v, bool):
        return float(v)
    m = _NUM.match(str(v).strip())
    return float(m.group(0)) if m else None


def extract_metrics(doc: dict) -> dict[str, float]:
    """Flatten a bench/serve JSON document into {metric: value}."""
    out: dict[str, float] = {}
    for row in doc.get("rows", []):
        name = row.get("name", "")
        us = _lead_float(row.get("us_per_call", ""))
        if us is not None:
            out[f"{name}.us"] = us
        for part in str(row.get("derived", "")).split(";"):
            if "=" not in part:
                continue
            k, v = part.split("=", 1)
            f = _lead_float(v)
            if f is not None:
                out[f"{name}.{k.strip()}"] = f
    for prefix in ("stats", "metrics"):
        for k, v in doc.get(prefix, {}).items():
            if isinstance(v, dict):   # histogram summary
                for sub, sv in v.items():
                    f = _lead_float(sv)
                    if f is not None:
                        out[f"{prefix}.{k}.{sub}"] = f
            else:
                f = _lead_float(v)
                if f is not None:
                    out[f"{prefix}.{k}"] = f
    return out


def compare(current: dict[str, float], baseline: dict) -> list[str]:
    """Apply the baseline's gates; returns failure messages (empty =
    pass).  Prints one PASS/ok line per gate so the CI log shows what
    was checked, not just that something was."""
    failures = []
    base_vals = baseline.get("metrics", {})
    gates = baseline.get("gates", [])
    if not gates:
        failures.append("baseline has no gates (nothing would be "
                        "checked — author at least one)")
    for g in gates:
        name = g["metric"]
        direction = g.get("direction", "higher")
        if direction not in ("higher", "lower"):
            failures.append(f"{name}: bad direction {direction!r}")
            continue
        if name not in base_vals:
            failures.append(f"{name}: gated but missing from the "
                            "baseline's metrics (seed it with --update)")
            continue
        base = float(base_vals[name])
        if name not in current:
            failures.append(f"{name}: missing from the current run "
                            "(row dropped or renamed?)")
            continue
        cur = current[name]
        band = (float(g.get("abs_tol", 0.0))
                + float(g.get("rel_tol", 0.0)) * abs(base))
        bad = (cur < base - band if direction == "higher"
               else cur > base + band)
        verdict = "FAIL" if bad else "ok"
        print(f"  [{verdict}] {name}: current={cur:g} baseline={base:g} "
              f"band=±{band:g} ({direction} is better)")
        if bad:
            failures.append(
                f"{name}: {cur:g} is out of band vs baseline {base:g} "
                f"(allowed {direction}-side slack {band:g})")
    return failures


def update_baseline(path: str, baseline: dict,
                    current: dict[str, float]) -> int:
    """Rewrite the baseline's gated metrics from the current run."""
    missing = [g["metric"] for g in baseline.get("gates", [])
               if g["metric"] not in current]
    if missing:
        print(f"cannot update: gated metrics missing from the current "
              f"run: {missing}", file=sys.stderr)
        return 1
    baseline["metrics"] = {g["metric"]: current[g["metric"]]
                           for g in baseline.get("gates", [])}
    with open(path, "w") as f:
        json.dump(baseline, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"updated {path} ({len(baseline['metrics'])} metrics)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Gate a bench/serve JSON artifact against a "
                    "checked-in perf baseline")
    ap.add_argument("current", help="JSON from --json / --metrics FILE")
    ap.add_argument("baseline", help="benchmarks/baselines/*.json")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline's metrics from the "
                         "current run (gates stay as authored)")
    ap.add_argument("--list", action="store_true",
                    help="print every metric extracted from the current "
                         "file and exit (for authoring gates)")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = extract_metrics(json.load(f))
    if args.list:
        for k in sorted(current):
            print(f"{k} = {current[k]:g}")
        return 0
    with open(args.baseline) as f:
        baseline = json.load(f)
    if args.update:
        return update_baseline(args.baseline, baseline, current)
    print(f"[bench_compare] {args.current} vs {args.baseline}")
    failures = compare(current, baseline)
    for msg in failures:
        print(f"[bench_compare] REGRESSION: {msg}", file=sys.stderr)
    if not failures:
        print(f"[bench_compare] all {len(baseline.get('gates', []))} "
              "gates passed")
    return len(failures)


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:        # e.g. --list | head
        sys.exit(0)
